"""Top-level model builder: one functional bundle per architecture family.

``build_model(cfg)`` returns a :class:`ModelBundle` with pure functions:

  init(rng)                          -> params
  loss(params, batch)                -> scalar CE loss        (train_step)
  prefill(params, batch, cache)      -> (last logits, cache)  (prefill_step)
  decode(params, tokens, cache)      -> (logits, cache)       (serve_step)
  init_cache(batch_size, max_len)    -> cache pytree

Layer stacks are lax.scan'd (leading layer/group axis on params and caches)
so compiled HLO size is O(1) in depth — required for the 40-cell x 2-mesh
dry-run budget.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import transformer as tf
from repro.models.attention import init_kv_cache
from repro.models.layers import (
    cross_entropy_loss,
    dense_init,
    dtype_of,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    sinusoidal_embed,
)
from repro.models.ssm import init_mamba_cache, mamba2_apply, mamba2_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable


def build_model(cfg: ArchConfig) -> ModelBundle:
    if cfg.family in ("dense", "vlm"):
        return _build_dense(cfg)
    if cfg.family == "moe":
        return _build_moe(cfg)
    if cfg.family == "ssm":
        return _build_ssm(cfg)
    if cfg.family == "hybrid":
        return _build_zamba(cfg)
    if cfg.family == "audio":
        return _build_whisper(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


# ---------------------------------------------------------------------------
# shared pieces


def _head_init(key, cfg) -> Params:
    ke, kh = jax.random.split(key)
    dtype = dtype_of(cfg.param_dtype)
    v = cfg.vocab_padded  # Megatron-style padding keeps vocab TP-shardable
    p = {"embed": embed_init(ke, v, cfg.d_model, dtype), "ln_f": rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kh, cfg.d_model, v, dtype)
    return p


def _logits(params: Params, h: jnp.ndarray, cfg) -> jnp.ndarray:
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    w = w.astype(h.dtype)
    if cfg.mesh_axes and cfg.axis_size("model") > 1:
        from jax.sharding import PartitionSpec as P

        # force the all-gather-weight strategy: contract over a REPLICATED
        # d_model and emit vocab-sharded logits, instead of GSPMD's partial-sum
        # all-reduce of the full fp32 logits tensor (§Perf iter 2)
        w = jax.lax.with_sharding_constraint(w, P(None, "model"))
        logits = h @ w
        dp = cfg.dp_axes()
        bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
        spec = [None] * logits.ndim
        spec[-1] = "model"
        if logits.shape[0] % max(int(np.prod([cfg.axis_size(a) for a in (dp or ())])), 1) == 0 and dp:
            spec[0] = bspec
        logits = jax.lax.with_sharding_constraint(logits, P(*spec))
    else:
        logits = h @ w
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.finfo(logits.dtype).min, logits)
    return logits


def _embed(params: Params, tokens: jnp.ndarray, cfg) -> jnp.ndarray:
    return params["embed"].astype(dtype_of(cfg.dtype))[tokens]


def _lm_loss(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return cross_entropy_loss(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# dense (+ vlm = dense backbone + projector stub)


def _build_dense(cfg: ArchConfig) -> ModelBundle:
    is_vlm = cfg.family == "vlm"

    def init(rng) -> Params:
        k_head, k_layers, k_proj = jax.random.split(rng, 3)
        p = _head_init(k_head, cfg)
        p["layers"] = tf.stack_init(k_layers, cfg.n_layers, lambda k: tf.dense_block_init(k, cfg))
        if is_vlm:
            k1, k2 = jax.random.split(k_proj)
            dtype = dtype_of(cfg.param_dtype)
            p["projector"] = {
                "w1": dense_init(k1, cfg.vision_dim, cfg.d_model, dtype),
                "w2": dense_init(k2, cfg.d_model, cfg.d_model, dtype),
            }
        return p

    def backbone(params, x, cache=None, from_zero=False):
        body = tf.remat_wrap(
            lambda h, pc: tf.dense_block_apply(pc[0], h, cfg, cache=pc[1], from_zero=from_zero),
            cfg.remat,
        )
        x, new_cache = jax.lax.scan(lambda h, pc: body(h, pc), x, (params["layers"], cache))
        return x, new_cache

    def inputs_from_batch(params, batch):
        x = _embed(params, batch["tokens"], cfg)
        if is_vlm:
            pe = batch["patches"].astype(x.dtype)
            pe = jax.nn.gelu(pe @ params["projector"]["w1"]) @ params["projector"]["w2"]
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def loss(params, batch):
        x = inputs_from_batch(params, batch)
        h, _ = backbone(params, x, cache=None)
        logits = _logits(params, h, cfg)
        if is_vlm:
            v = cfg.vision_tokens
            return cross_entropy_loss(logits[:, v - 1 : -1], batch["tokens"])
        return _lm_loss(logits, batch["tokens"])

    def init_cache(batch_size: int, max_len: int):
        dtype = dtype_of(cfg.dtype)
        one = lambda _k: init_kv_cache(batch_size, cfg.n_kv_heads, max_len, cfg.resolved_head_dim, dtype)
        return jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one(i) for i in range(cfg.n_layers)]
        )

    def prefill(params, batch, cache):
        x = inputs_from_batch(params, batch)
        h, cache = backbone(params, x, cache=cache, from_zero=True)
        return _logits(params, h[:, -1:], cfg), cache

    def decode(params, tokens, cache):
        x = _embed(params, tokens, cfg)
        h, cache = backbone(params, x, cache=cache)
        return _logits(params, h, cfg), cache

    return ModelBundle(cfg, init, loss, prefill, decode, init_cache)


# ---------------------------------------------------------------------------
# MoE


def _build_moe(cfg: ArchConfig) -> ModelBundle:
    n_groups = cfg.n_layers // cfg.moe_every
    assert n_groups * cfg.moe_every == cfg.n_layers, "moe_every must divide n_layers"

    def init(rng) -> Params:
        k_head, k_groups = jax.random.split(rng)
        p = _head_init(k_head, cfg)
        p["groups"] = tf.stack_init(k_groups, n_groups, lambda k: tf.moe_group_init(k, cfg))
        return p

    def backbone(params, x, cache=None, from_zero=False):
        body = tf.remat_wrap(
            lambda h, pc: tf.moe_group_apply(pc[0], h, cfg, caches=pc[1], from_zero=from_zero),
            cfg.remat,
        )
        x, new_cache = jax.lax.scan(lambda h, pc: body(h, pc), x, (params["groups"], cache))
        return x, new_cache

    def loss(params, batch):
        x = _embed(params, batch["tokens"], cfg)
        h, _ = backbone(params, x, cache=None)
        return _lm_loss(_logits(params, h, cfg), batch["tokens"])

    def init_cache(batch_size: int, max_len: int):
        dtype = dtype_of(cfg.dtype)
        kv = lambda: init_kv_cache(batch_size, cfg.n_kv_heads, max_len, cfg.resolved_head_dim, dtype)

        def one_group(_i):
            c = {"moe": kv()}
            if cfg.moe_every > 1:
                c["dense"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *[kv() for _ in range(cfg.moe_every - 1)]
                )
            return c

        return jax.tree.map(lambda *xs: jnp.stack(xs), *[one_group(i) for i in range(n_groups)])

    def prefill(params, batch, cache):
        x = _embed(params, batch["tokens"], cfg)
        h, cache = backbone(params, x, cache=cache, from_zero=True)
        return _logits(params, h[:, -1:], cfg), cache

    def decode(params, tokens, cache):
        x = _embed(params, tokens, cfg)
        h, cache = backbone(params, x, cache=cache)
        return _logits(params, h, cfg), cache

    return ModelBundle(cfg, init, loss, prefill, decode, init_cache)


# ---------------------------------------------------------------------------
# SSM (mamba2)


def _build_ssm(cfg: ArchConfig) -> ModelBundle:
    def init(rng) -> Params:
        k_head, k_layers = jax.random.split(rng)
        p = _head_init(k_head, cfg)
        p["layers"] = tf.stack_init(k_layers, cfg.n_layers, lambda k: mamba2_init(k, cfg))
        return p

    def backbone(params, x, cache=None, from_zero=False):
        del from_zero  # attention-free
        def block(h, pc):
            out, nc = mamba2_apply(pc[0], h, cfg, cache=pc[1])
            return h + out, nc

        body = tf.remat_wrap(block, cfg.remat)
        x, new_cache = jax.lax.scan(lambda h, pc: body(h, pc), x, (params["layers"], cache))
        return x, new_cache

    def loss(params, batch):
        x = _embed(params, batch["tokens"], cfg)
        h, _ = backbone(params, x, cache=None)
        return _lm_loss(_logits(params, h, cfg), batch["tokens"])

    def init_cache(batch_size: int, max_len: int):
        # max_len is irrelevant: O(1) state (this is the long_500k superpower)
        dtype = dtype_of(cfg.dtype)
        one = lambda: init_mamba_cache(batch_size, cfg, dtype)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[one() for _ in range(cfg.n_layers)])

    def prefill(params, batch, cache):
        x = _embed(params, batch["tokens"], cfg)
        h, cache = backbone(params, x, cache=cache, from_zero=True)
        return _logits(params, h[:, -1:], cfg), cache

    def decode(params, tokens, cache):
        x = _embed(params, tokens, cfg)
        h, cache = backbone(params, x, cache=cache)
        return _logits(params, h, cfg), cache

    return ModelBundle(cfg, init, loss, prefill, decode, init_cache)


# ---------------------------------------------------------------------------
# Zamba2 hybrid


def _build_zamba(cfg: ArchConfig) -> ModelBundle:
    n_groups = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers - n_groups * cfg.attn_every

    def init(rng) -> Params:
        k_head, k_shared, k_groups, k_tail = jax.random.split(rng, 4)
        p = _head_init(k_head, cfg)
        p["shared"] = tf.zamba_shared_init(k_shared, cfg)
        p["groups"] = tf.stack_init(k_groups, n_groups, lambda k: tf.zamba_group_init(k, cfg))
        if tail:
            p["tail"] = tf.stack_init(k_tail, tail, lambda k: mamba2_init(k, cfg))
        return p

    def backbone(params, x, cache=None, from_zero=False):
        embed0 = x  # original embeddings, re-fed to every shared-attn call

        def group(h, pc):
            h, nc = tf.zamba_group_apply(
                pc[0], params["shared"], h, embed0, cfg, caches=pc[1], from_zero=from_zero
            )
            return h, nc

        body = tf.remat_wrap(group, cfg.remat)
        g_cache = cache["groups"] if cache is not None else None
        x, new_g = jax.lax.scan(lambda h, pc: body(h, pc), x, (params["groups"], g_cache))
        new_t = None
        if tail:
            t_cache = cache["tail"] if cache is not None else None

            def tail_block(h, pc):
                out, nc = mamba2_apply(pc[0], h, cfg, cache=pc[1])
                return h + out, nc

            x, new_t = jax.lax.scan(lambda h, pc: tail_block(h, pc), x, (params["tail"], t_cache))
        if cache is None:
            return x, None
        out_cache = {"groups": new_g}
        if tail:
            out_cache["tail"] = new_t
        return x, out_cache

    def loss(params, batch):
        x = _embed(params, batch["tokens"], cfg)
        h, _ = backbone(params, x, cache=None)
        return _lm_loss(_logits(params, h, cfg), batch["tokens"])

    def init_cache(batch_size: int, max_len: int):
        dtype = dtype_of(cfg.dtype)
        kv = lambda: init_kv_cache(batch_size, cfg.n_kv_heads, max_len, cfg.resolved_head_dim, dtype)
        mc = lambda: init_mamba_cache(batch_size, cfg, dtype)

        def one_group(_i):
            return {
                "attn": kv(),
                "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *[mc() for _ in range(cfg.attn_every)]),
            }

        c = {"groups": jax.tree.map(lambda *xs: jnp.stack(xs), *[one_group(i) for i in range(n_groups)])}
        if tail:
            c["tail"] = jax.tree.map(lambda *xs: jnp.stack(xs), *[mc() for _ in range(tail)])
        return c

    def prefill(params, batch, cache):
        x = _embed(params, batch["tokens"], cfg)
        h, cache = backbone(params, x, cache=cache, from_zero=True)
        return _logits(params, h[:, -1:], cfg), cache

    def decode(params, tokens, cache):
        x = _embed(params, tokens, cfg)
        h, cache = backbone(params, x, cache=cache)
        return _logits(params, h, cfg), cache

    return ModelBundle(cfg, init, loss, prefill, decode, init_cache)


# ---------------------------------------------------------------------------
# Whisper (encoder-decoder)


def _build_whisper(cfg: ArchConfig) -> ModelBundle:
    def init(rng) -> Params:
        k_head, k_enc, k_dec = jax.random.split(rng, 3)
        p = _head_init(k_head, cfg)
        p["encoder"] = tf.stack_init(k_enc, cfg.encoder_layers, lambda k: tf.encoder_block_init(k, cfg))
        p["decoder"] = tf.stack_init(k_dec, cfg.n_layers, lambda k: tf.decoder_xblock_init(k, cfg))
        return p

    def encode(params, frames):
        x = frames.astype(dtype_of(cfg.dtype))
        x = x + sinusoidal_embed(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)[None]

        def body(h, p_layer):
            return tf.encoder_block_apply(p_layer, h, cfg), None

        x, _ = jax.lax.scan(tf.remat_wrap(body, cfg.remat), x, params["encoder"])
        return x

    def cross_kvs(params, enc_out):
        def one(p_layer):
            return tf.cross_kv_from_encoder(p_layer, enc_out, cfg)

        return jax.vmap(one, in_axes=0, out_axes=0)(params["decoder"])

    def run_decoder(params, x, kvs, cache=None, from_zero=False):
        def body(h, pkc):
            p_layer, kv_layer, c_layer = pkc
            h, nc = tf.decoder_xblock_apply(
                p_layer, h, kv_layer, cfg, cache=c_layer, from_zero=from_zero
            )
            return h, nc

        x, new_cache = jax.lax.scan(
            tf.remat_wrap(body, cfg.remat), x, (params["decoder"], kvs, cache)
        )
        return x, new_cache

    def dec_embed(params, tokens, pos0):
        x = _embed(params, tokens, cfg)
        s = x.shape[1]
        positions = pos0 + jnp.arange(s)
        return x + sinusoidal_embed(positions, cfg.d_model).astype(x.dtype)[None]

    def loss(params, batch):
        enc = encode(params, batch["frames"])
        kvs = cross_kvs(params, enc)
        x = dec_embed(params, batch["tokens"], 0)
        h, _ = run_decoder(params, x, kvs, cache=None)
        return _lm_loss(_logits(params, h, cfg), batch["tokens"])

    def init_cache(batch_size: int, max_len: int):
        dtype = dtype_of(cfg.dtype)
        kv = lambda: init_kv_cache(batch_size, cfg.n_kv_heads, max_len, cfg.resolved_head_dim, dtype)
        self_c = jax.tree.map(lambda *xs: jnp.stack(xs), *[kv() for _ in range(cfg.n_layers)])
        hd = cfg.resolved_head_dim
        cross = (
            jnp.zeros((cfg.n_layers, batch_size, cfg.n_kv_heads, cfg.encoder_seq, hd), dtype=dtype),
            jnp.zeros((cfg.n_layers, batch_size, cfg.n_kv_heads, cfg.encoder_seq, hd), dtype=dtype),
        )
        return {"self": self_c, "cross": cross}

    def prefill(params, batch, cache):
        enc = encode(params, batch["frames"])
        kvs = cross_kvs(params, enc)
        x = dec_embed(params, batch["tokens"], 0)
        h, self_c = run_decoder(params, x, kvs, cache=cache["self"], from_zero=True)
        return _logits(params, h[:, -1:], cfg), {"self": self_c, "cross": kvs}

    def decode(params, tokens, cache):
        pos = cache["self"]["pos"][0]
        x = dec_embed(params, tokens, pos)
        h, self_c = run_decoder(params, x, cache["cross"], cache=cache["self"])
        return _logits(params, h, cfg), {"self": self_c, "cross": cache["cross"]}

    return ModelBundle(cfg, init, loss, prefill, decode, init_cache)
