"""Block compositions for all model families, scan-stacked for O(1)-depth HLO.

Every block is ``apply(params, x, ..., cache) -> (x, cache)``; stacks carry
per-layer params/caches with a leading layer (or layer-group) axis consumed by
``lax.scan``.  Remat policy wraps the scan body.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention_apply, attention_init, init_kv_cache
from repro.models.layers import gelu_mlp, gelu_mlp_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import init_mamba_cache, mamba2_apply, mamba2_init

Params = Dict[str, Any]


def stack_init(key, n: int, init_fn):
    """Initialize n copies of a block; returns pytree with leading axis n."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    if remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(f"unknown remat {remat!r}")


# ---------------------------------------------------------------------------
# dense decoder block (qwen2 / granite / minitron / mistral backbone)


def dense_block_init(key, cfg) -> Params:
    ka, km = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln_attn": rmsnorm_init(cfg.d_model, dtype),
        "attn": attention_init(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, cfg.qkv_bias, dtype
        ),
        "ln_mlp": rmsnorm_init(cfg.d_model, dtype),
        "mlp": swiglu_init(km, cfg.d_model, cfg.d_ff, dtype),
    }


def dense_block_apply(params: Params, x, cfg, cache=None, positions=None, from_zero=False):
    h, new_cache = attention_apply(
        params["attn"],
        rmsnorm(params["ln_attn"], x, cfg.norm_eps),
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        impl=cfg.attention_impl,
        pos_type=cfg.pos_type,
        rope_theta=cfg.rope_theta,
        positions=positions,
        cache=cache,
        causal_scheduling=cfg.causal_scheduling,
        mesh_axes=cfg.mesh_axes if cfg.shard_attn_activations else (),
        from_zero=from_zero,
    )
    x = x + h
    x = x + swiglu(params["mlp"], rmsnorm(params["ln_mlp"], x, cfg.norm_eps))
    return x, new_cache


# ---------------------------------------------------------------------------
# MoE layer group (moe_every layers; last one MoE, earlier ones dense)


def moe_group_init(key, cfg) -> Params:
    keys = jax.random.split(key, cfg.moe_every + 1)
    dtype = jnp.dtype(cfg.dtype)
    group = {"dense": [], "moe": None}
    blocks = []
    for i in range(cfg.moe_every - 1):
        blocks.append(dense_block_init(keys[i], cfg))
    group_dense = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks) if blocks else None
    ka, km = jax.random.split(keys[-1])
    moe_block = {
        "ln_attn": rmsnorm_init(cfg.d_model, dtype),
        "attn": attention_init(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, cfg.qkv_bias, dtype
        ),
        "ln_mlp": rmsnorm_init(cfg.d_model, dtype),
        "moe": moe_init(km, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.shared_expert, dtype,
                        n_experts_padded=cfg.n_experts_padded),
    }
    out = {"moe_block": moe_block}
    if group_dense is not None:
        out["dense_blocks"] = group_dense
    return out


def moe_group_apply(params: Params, x, cfg, caches=None, positions=None, from_zero=False):
    """caches: dict {"dense": stacked cache (moe_every-1, ...) or None,
    "moe": cache} matching the group structure."""
    new_caches = {}
    if "dense_blocks" in params:
        n_dense = cfg.moe_every - 1
        dense_caches = caches["dense"] if caches is not None else None
        new_dense = []
        for i in range(n_dense):
            p_i = jax.tree.map(lambda a: a[i], params["dense_blocks"])
            c_i = jax.tree.map(lambda a: a[i], dense_caches) if dense_caches is not None else None
            x, nc = dense_block_apply(p_i, x, cfg, cache=c_i, positions=positions, from_zero=from_zero)
            new_dense.append(nc)
        if caches is not None:
            new_caches["dense"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_dense)
    mb = params["moe_block"]
    c_moe = caches["moe"] if caches is not None else None
    h, nc = attention_apply(
        mb["attn"],
        rmsnorm(mb["ln_attn"], x, cfg.norm_eps),
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        impl=cfg.attention_impl,
        pos_type=cfg.pos_type,
        rope_theta=cfg.rope_theta,
        positions=positions,
        cache=c_moe,
        causal_scheduling=cfg.causal_scheduling,
        mesh_axes=cfg.mesh_axes if cfg.shard_attn_activations else (),
        from_zero=from_zero,
    )
    x = x + h
    x = x + moe_apply(
        mb["moe"], rmsnorm(mb["ln_mlp"], x, cfg.norm_eps), top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        mesh_axes=cfg.mesh_axes if cfg.shard_attn_activations else (),
    )
    if caches is not None:
        new_caches["moe"] = nc
    return x, (new_caches if caches is not None else None)


# ---------------------------------------------------------------------------
# Zamba2-style hybrid group: attn_every mamba blocks + weight-shared attention


def zamba_shared_init(key, cfg) -> Params:
    ka, km, kp = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln_in": rmsnorm_init(2 * cfg.d_model, dtype),
        "in_proj": (jax.random.normal(kp, (2 * cfg.d_model, cfg.d_model), dtype=jnp.float32) / jnp.sqrt(2.0 * cfg.d_model)).astype(dtype),
        "attn": attention_init(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, cfg.qkv_bias, dtype
        ),
        "ln_mlp": rmsnorm_init(cfg.d_model, dtype),
        "mlp": swiglu_init(km, cfg.d_model, cfg.d_ff, dtype),
    }


def zamba_group_init(key, cfg) -> Params:
    keys = jax.random.split(key, cfg.attn_every)
    blocks = [mamba2_init(k, cfg) for k in keys]
    return {"mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)}


def zamba_group_apply(params: Params, shared: Params, x, embed0, cfg, caches=None, positions=None, from_zero=False):
    """One group: shared attention block (fed concat(x, embed0)) then
    attn_every mamba blocks.  caches: {"attn": kv cache, "mamba": stacked}."""
    c_attn = caches["attn"] if caches is not None else None
    concat = jnp.concatenate([x, embed0], axis=-1)
    h = rmsnorm(shared["ln_in"], concat, cfg.norm_eps) @ shared["in_proj"]
    a, new_attn = attention_apply(
        shared["attn"],
        h,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        impl=cfg.attention_impl,
        pos_type=cfg.pos_type,
        rope_theta=cfg.rope_theta,
        positions=positions,
        cache=c_attn,
        causal_scheduling=cfg.causal_scheduling,
        mesh_axes=cfg.mesh_axes if cfg.shard_attn_activations else (),
        from_zero=from_zero,
    )
    x = x + a
    x = x + swiglu(shared["mlp"], rmsnorm(shared["ln_mlp"], x, cfg.norm_eps))

    new_mamba = []
    for i in range(cfg.attn_every):
        p_i = jax.tree.map(lambda t: t[i], params["mamba"])
        c_i = (
            jax.tree.map(lambda t: t[i], caches["mamba"]) if caches is not None else None
        )
        out, nc = mamba2_apply(p_i, x, cfg, cache=c_i)
        x = x + out
        new_mamba.append(nc)
    new_caches = None
    if caches is not None:
        new_caches = {
            "attn": new_attn,
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba),
        }
    return x, new_caches


# ---------------------------------------------------------------------------
# Whisper blocks


def encoder_block_init(key, cfg) -> Params:
    ka, km = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln_attn": rmsnorm_init(cfg.d_model, dtype),
        "attn": attention_init(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, False, dtype
        ),
        "ln_mlp": rmsnorm_init(cfg.d_model, dtype),
        "mlp": gelu_mlp_init(km, cfg.d_model, cfg.d_ff, dtype),
    }


def encoder_block_apply(params: Params, x, cfg):
    h, _ = attention_apply(
        params["attn"],
        rmsnorm(params["ln_attn"], x, cfg.norm_eps),
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        impl=cfg.attention_impl if cfg.attention_impl == "naive" else "naive",
        causal=False,
        pos_type="none",
    )
    x = x + h
    x = x + gelu_mlp(params["mlp"], rmsnorm(params["ln_mlp"], x, cfg.norm_eps))
    return x


def decoder_xblock_init(key, cfg) -> Params:
    ka, kc, km = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln_self": rmsnorm_init(cfg.d_model, dtype),
        "self_attn": attention_init(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, False, dtype
        ),
        "ln_cross": rmsnorm_init(cfg.d_model, dtype),
        "cross_attn": attention_init(
            kc, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, False, dtype
        ),
        "ln_mlp": rmsnorm_init(cfg.d_model, dtype),
        "mlp": gelu_mlp_init(km, cfg.d_model, cfg.d_ff, dtype),
    }


def decoder_xblock_apply(params: Params, x, enc_kv, cfg, cache=None, positions=None, from_zero=False):
    """enc_kv: (k, v) precomputed from encoder output for this layer."""
    h, new_cache = attention_apply(
        params["self_attn"],
        rmsnorm(params["ln_self"], x, cfg.norm_eps),
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        impl=cfg.attention_impl,
        pos_type="none",  # whisper uses learned/sinusoidal absolute positions
        positions=positions,
        cache=cache,
        causal_scheduling=cfg.causal_scheduling,
        mesh_axes=cfg.mesh_axes if cfg.shard_attn_activations else (),
        from_zero=from_zero,
    )
    x = x + h
    c, _ = attention_apply(
        params["cross_attn"],
        rmsnorm(params["ln_cross"], x, cfg.norm_eps),
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        impl="naive",
        cross_kv=enc_kv,
        pos_type="none",
    )
    x = x + c
    x = x + gelu_mlp(params["mlp"], rmsnorm(params["ln_mlp"], x, cfg.norm_eps))
    return x, new_cache


def cross_kv_from_encoder(params: Params, enc_out, cfg):
    """Precompute per-layer cross K/V from encoder output (prefill-time)."""
    from repro.models.attention import qkv_slices

    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    _, wk, wv = qkv_slices(params["cross_attn"], cfg.n_heads, cfg.n_kv_heads, hd)
    k = (enc_out @ wk).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (enc_out @ wv).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return k, v
