"""repro — FFCz: spectrum-preserving lossy compression, as a production JAX framework.

Layers:
  core/         the paper's contribution: dual-domain (spatial+frequency)
                error-bounded correction via alternating projection (POCS).
  compressors/  JAX reimplementations of the algorithmic cores of the base
                compressors the paper builds on (SZ3-like, ZFP-like, SPERR-like).
  coding/       entropy coding (Huffman + zlib-as-ZSTD), bit packing, quantizers.
  kernels/      Pallas TPU kernels for the hot paths (+ pure-jnp oracles).
  models/       the 10 assigned LM architectures (dense/GQA, MoE, SSM, hybrid,
                VLM-stub, audio-stub) as pure-JAX functional modules.
  sharding/     DP/TP/EP/SP/PP partition rules over the production mesh.
  optim/        AdamW + FFCz-compressed gradient all-reduce.
  checkpoint/   atomic, resharding-capable checkpointing with FFCz codec.
  runtime/      fault-tolerant trainer (restart, straggler mitigation, elastic).
  serving/      batched decode engine with FFCz KV-cache compression.
  launch/       production mesh, multi-pod dry-run, train/serve entry points.
"""

__version__ = "1.0.0"
