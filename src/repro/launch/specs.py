"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation).

``input_specs(cfg, shape_id)`` returns the abstract arguments for the step
function of that cell kind:

  train:   {"batch": {...}}                               -> train_step
  prefill: {"batch": {...}, "cache": fresh-cache specs}   -> prefill_step
  decode:  {"tokens": (B,1), "cache": full-length specs}  -> serve_step

Modality frontends are STUBS: audio provides precomputed frame embeddings,
vlm provides precomputed patch embeddings (assignment spec).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, ArchConfig
from repro.models.layers import dtype_of
from repro.models.model import build_model


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ArchConfig, seq: int, batch: int) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    act = dtype_of(cfg.dtype)
    if cfg.family == "vlm":
        text = seq - cfg.vision_tokens
        assert text > 0, "vlm sequence must exceed vision token count"
        out["tokens"] = _sds((batch, text), jnp.int32)
        out["patches"] = _sds((batch, cfg.vision_tokens, cfg.vision_dim), act)
    elif cfg.family == "audio":
        out["tokens"] = _sds((batch, seq), jnp.int32)
        out["frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model), act)
    else:
        out["tokens"] = _sds((batch, seq), jnp.int32)
    return out


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> Any:
    bundle = build_model(cfg)
    return jax.eval_shape(lambda: bundle.init_cache(batch, max_len))


def param_specs(cfg: ArchConfig) -> Any:
    bundle = build_model(cfg)
    return jax.eval_shape(bundle.init, jax.random.PRNGKey(0))


def input_specs(cfg: ArchConfig, shape_id: str) -> Dict[str, Any]:
    seq, batch, kind = SHAPES[shape_id]
    if kind == "train":
        return {"batch": batch_specs(cfg, seq, batch)}
    if kind == "prefill":
        return {
            "batch": batch_specs(cfg, seq, batch),
            "cache": cache_specs(cfg, batch, seq),
        }
    if kind == "decode":
        return {
            "tokens": _sds((batch, 1), jnp.int32),
            "cache": cache_specs(cfg, batch, seq),
        }
    raise ValueError(shape_id)
