"""Trip-count-aware cost analysis of optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` visits every computation ONCE —
a ``lax.scan`` over 48 layers reports 1/48th of the real FLOPs.  Since the
whole framework scan-stacks its layers (O(1)-depth HLO is what makes the
40-cell x 2-mesh dry-run tractable), we walk the HLO call graph ourselves
and scale ``while`` bodies by their ``known_trip_count`` backend config.

Cost model (per-device, the compiled module is the SPMD per-device program):

  flops            dot: 2 * prod(result) * prod(contracting dims); one
                   flop/element for arithmetic/transcendental elementwise ops
                   (inside fusion bodies too); FFT custom-calls: 5 N log2 N.
  bytes            HBM traffic proxy: operand + result bytes of top-level
                   (post-fusion) instructions; fusion internals are VMEM-local
                   and contribute no HBM bytes.
  collectives      result bytes of all-reduce / all-gather / reduce-scatter /
                   all-to-all / collective-permute, per op kind.

While bodies with unknown trip count (dynamic fori_loop, e.g. POCS or the
causal prefill sweep) count once and are flagged in ``unknown_trips``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "maximum",
    "minimum", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "cbrt", "power", "sine", "cosine", "atan2",
    "logistic", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "clamp", "select", "compare", "erf",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"\((%[\w.\-]+|[a-z][a-z0-9]*\[[0-9,]*\][^,)]*)")


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Total (elements, bytes) over all array shapes in a type string."""
    elems = tot = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES[dt]
    return elems, tot


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trips: int = 0
    # trip-aware attribution: (op kind, source op_name) -> bytes
    coll_by_name: Dict[Tuple[str, str], float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v
        for k, v in other.coll_by_name.items():
            self.coll_by_name[k] = self.coll_by_name.get(k, 0.0) + v
        self.unknown_trips += other.unknown_trips
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n,
                    {k: v * n for k, v in self.collectives.items()}, self.unknown_trips,
                    {k: v * n for k, v in self.coll_by_name.items()})


class _Instruction:
    __slots__ = ("name", "rhs", "opcode", "result_type")

    def __init__(self, name: str, rhs: str):
        self.name = name
        self.rhs = rhs
        # result type = everything before the opcode token
        m = re.match(r"((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\(", rhs)
        if m:
            self.result_type = m.group(1)
            self.opcode = m.group(2)
        else:
            self.result_type = ""
            self.opcode = ""


def _split_computations(text: str) -> Dict[str, List[_Instruction]]:
    comps: Dict[str, List[_Instruction]] = {}
    cur: Optional[str] = None
    body: List[_Instruction] = []
    for line in text.splitlines():
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and ("(" in line):
            m = re.match(r"(?:ENTRY\s+)?(%[\w.\-]+)\s*\(", line)
            if m:
                cur = m.group(1)
                body = []
                comps[cur] = body
                if "ENTRY" in line:
                    comps["__entry__"] = body
                continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _DEF_RE.match(line)
        if m:
            body.append(_Instruction(m.group(1), m.group(2)))
    return comps


def analyze_hlo(text: str) -> Cost:
    comps = _split_computations(text)
    shapes: Dict[str, str] = {}
    for name, body in comps.items():
        if name == "__entry__":
            continue
        for ins in body:
            shapes[ins.name] = ins.result_type

    memo: Dict[Tuple[str, bool], Cost] = {}

    def comp_cost(cname: str, in_fusion: bool) -> Cost:
        key = (cname, in_fusion)
        if key in memo:
            return memo[key]
        total = Cost()
        for ins in comps.get(cname, []):
            total += ins_cost(ins, in_fusion)
        memo[key] = total
        return total

    def ins_cost(ins: _Instruction, in_fusion: bool) -> Cost:
        op = ins.opcode
        c = Cost()
        res_elems, res_bytes = _shape_elems_bytes(ins.result_type)

        if op == "fusion":
            m = _CALLS_RE.search(ins.rhs)
            if m:
                c += comp_cost(m.group(1), True)
            if not in_fusion:
                c.bytes += res_bytes + _operand_bytes(ins)
            return c
        if op == "while":
            body_m = _CALLS_RE.search(ins.rhs)
            cond_m = _COND_RE.search(ins.rhs)
            trip_m = _TRIP_RE.search(ins.rhs)
            trip = int(trip_m.group(1)) if trip_m else 1
            inner = Cost()
            if body_m:
                inner += comp_cost(body_m.group(1), in_fusion)
            if cond_m:
                inner += comp_cost(cond_m.group(1), in_fusion)
            c += inner.scaled(trip)
            if not trip_m:
                c.unknown_trips += 1
            return c
        if op == "conditional":
            m = _BRANCHES_RE.search(ins.rhs)
            if m:
                branches = [b.strip() for b in m.group(1).split(",") if b.strip()]
                costs = [comp_cost(b, in_fusion) for b in branches]
                if costs:
                    best = max(costs, key=lambda x: x.flops + x.bytes)
                    c += best
            return c
        if op in ("call", "async-start", "custom-call") or op.startswith("async"):
            m = _CALLS_RE.search(ins.rhs)
            if m:
                c += comp_cost(m.group(1), in_fusion)
            if op == "custom-call" and ("fft" in ins.rhs.lower() or "Fft" in ins.rhs):
                import math

                n = max(res_elems, 1)
                c.flops += 5.0 * n * math.log2(max(n, 2))
            if not in_fusion:
                c.bytes += res_bytes + _operand_bytes(ins)
            return c
        if op == "fft":
            import math

            n = max(res_elems, 1)
            c.flops += 5.0 * n * math.log2(max(n, 2))
            if not in_fusion:
                c.bytes += res_bytes + _operand_bytes(ins)
            return c

        for coll in _COLLECTIVES:
            if op == coll or op == coll + "-start":
                c.collectives[coll] = c.collectives.get(coll, 0.0) + res_bytes
                nm = re.search(r'op_name="([^"]+)"', ins.rhs)
                key = (coll, nm.group(1) if nm else "?")
                c.coll_by_name[key] = c.coll_by_name.get(key, 0.0) + res_bytes
                return c

        if op in ("dot", "convolution"):
            k = 1
            m = _LHS_CONTRACT_RE.search(ins.rhs)
            lhs_shape = _first_operand_shape(ins, shapes)
            if m and lhs_shape:
                dims = [int(d) for d in m.group(1).split(",") if d]
                lhs_dims = _dims_of(lhs_shape)
                for d in dims:
                    if d < len(lhs_dims):
                        k *= lhs_dims[d]
            c.flops += 2.0 * res_elems * k
            if not in_fusion:
                c.bytes += res_bytes + _operand_bytes(ins)
            return c

        if op in _ELEMENTWISE_FLOP_OPS:
            c.flops += float(res_elems)
        if not in_fusion and op not in ("parameter", "constant", "tuple",
                                        "get-tuple-element", "bitcast"):
            c.bytes += res_bytes + _operand_bytes(ins)
        return c

    def _operand_bytes(ins: _Instruction) -> float:
        tot = 0.0
        inner = ins.rhs[ins.rhs.find("(") + 1 :]
        depth = 1
        buf = []
        for ch in inner:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        arglist = "".join(buf)
        for tok in re.findall(r"%[\w.\-]+", arglist):
            ty = shapes.get(tok)
            if ty:
                tot += _shape_elems_bytes(ty)[1]
        return tot

    def _first_operand_shape(ins: _Instruction, shapes_map) -> Optional[str]:
        # Operands may be bare references ("%dot.1, ...") or inline-typed
        # ("f32[128,128]{1,0} %p, ..."); a naive split on "," would truncate
        # the type at the comma *inside* the dims brackets, losing the
        # contracting-dim size (scan bodies hit this: their dot operands are
        # always inline-typed get-tuple-elements).
        start = ins.rhs.find("(")
        if start < 0:
            return None
        arg = ins.rhs[start + 1 :].lstrip()
        m = _SHAPE_RE.match(arg)
        if m:
            return m.group(0)  # inline-typed operand
        m = re.match(r"%[\w.\-]+", arg)
        if m:
            return shapes_map.get(m.group(0))
        return None

    def _dims_of(type_str: str) -> List[int]:
        m = _SHAPE_RE.search(type_str)
        if not m:
            return []
        return [int(d) for d in m.group(2).split(",") if d]

    return comp_cost("__entry__", False)
