"""FFCz compression service entry point with a built-in load generator.

Drives :class:`repro.serving.ffcz_service.FFCzService` with a synthetic
mixed workload (whole-field + pencil compressions + decodes, optionally a
``--session-frac`` slice of live-session frame appends with duplicate
retries, a fraction of decodes deliberately corrupted) under optional
deterministic fault injection, then prints the outcome table, latency
percentiles, stage timers, and the service's failure-machinery counters.
Session write-ahead journals are in-memory unless ``--session-journal-dir``
points at a directory for file-backed WALs.

    PYTHONPATH=src python -m repro.launch.serve_ffcz --requests 16
    PYTHONPATH=src python -m repro.launch.serve_ffcz --requests 32 \
        --p-codec 0.3 --p-dispatch 0.3 --p-oom 0.5 --p-slow 0.1 --slow-s 120 \
        --corrupt-frac 0.25 --seed 7
    # serial (un-pipelined) execution for A/B comparison:
    PYTHONPATH=src python -m repro.launch.serve_ffcz --requests 32 --pipeline-depth 1

The offered-load sweep lives in ``benchmarks/bench_serve.py``, which reuses
this module's flag groups (service, workload, faults) and adds
``--arrival-rates`` / ``--requests-per-run`` on top:

    PYTHONPATH=src python benchmarks/bench_serve.py --quick
    PYTHONPATH=src python benchmarks/bench_serve.py \
        --arrival-rates 5,20,80 --pencil-frac 0.75 --p-codec 0.1
"""

from __future__ import annotations

import argparse
from typing import List, Optional

import numpy as np

from repro.compressors import get_compressor
from repro.core.errors import ResourceExhausted
from repro.core.ffcz import FFCzConfig
from repro.core.temporal import TemporalConfig
from repro.runtime.faults import FaultConfig, FaultInjector
from repro.serving.ffcz_service import FFCzService, ServiceConfig


def add_service_args(ap: argparse.ArgumentParser) -> None:
    """Service-construction flags (shared with benchmarks/bench_serve.py)."""
    ap.add_argument("--seed", type=int, default=0, help="workload + fault stream seed")
    ap.add_argument("--base", default="szlike", help="base compressor name")
    ap.add_argument("--max-batch", type=int, default=8, help="pencil requests fused per step")
    ap.add_argument("--block", type=int, default=128, help="pencil length")
    ap.add_argument("--deadline-s", type=float, default=30.0, help="per-request deadline")
    ap.add_argument("--max-retries", type=int, default=3, help="transient retry budget")
    ap.add_argument(
        "--pipeline-depth", type=int, default=2,
        help="in-flight units: 1 = serial, >=2 overlaps host ENCODE with device EXECUTE",
    )
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission cap on queued requests (0 = unbounded)")
    ap.add_argument("--max-sessions", type=int, default=8,
                    help="admission cap on live stream sessions")
    ap.add_argument("--session-lease", type=float, default=60.0,
                    help="session lease seconds, refreshed on append")
    ap.add_argument("--session-journal-dir", default="",
                    help="directory for file-backed session WAL journals (default: in-memory)")


def add_workload_args(ap: argparse.ArgumentParser) -> None:
    """Synthetic-workload flags (shared with benchmarks/bench_serve.py)."""
    ap.add_argument("--field-size", type=int, default=24, help="whole-field edge length")
    ap.add_argument("--e-rel", type=float, default=1e-3, help="relative spatial bound")
    ap.add_argument("--delta-rel", type=float, default=1e-3, help="relative spectral bound")
    ap.add_argument("--crc", action="store_true", help="append CRC tails to field blobs")
    ap.add_argument("--pencil-frac", type=float, default=0.5,
                    help="fraction of compressions taking the blockwise path")
    ap.add_argument("--corrupt-frac", type=float, default=0.0,
                    help="fraction of decode requests fed corrupted bytes")
    ap.add_argument("--session-frac", type=float, default=0.0,
                    help="fraction of requests arriving as live-session frame appends")
    ap.add_argument("--session-frames", type=int, default=3,
                    help="frames per generated session (plus a duplicate retry + finalize)")


def add_fault_args(ap: argparse.ArgumentParser) -> None:
    """Fault-injection flags (all off by default; shared with the bench)."""
    ap.add_argument("--p-codec", type=float, default=0.0, help="host codec fault probability")
    ap.add_argument("--p-dispatch", type=float, default=0.0, help="device dispatch fault probability")
    ap.add_argument("--p-oom", type=float, default=0.0, help="device OOM probability")
    ap.add_argument("--p-slow", type=float, default=0.0, help="slow-request probability")
    ap.add_argument("--slow-s", type=float, default=0.0, help="injected slowness (seconds)")
    ap.add_argument("--p-session-append", type=float, default=0.0,
                    help="session append fault probability (pre-encode)")
    ap.add_argument("--p-session-journal", type=float, default=0.0,
                    help="session WAL write fault probability (post-encode)")
    ap.add_argument("--max-per-site", type=int, default=2,
                    help="fire cap per (fault site, request)")


def flag_table() -> str:
    """Markdown table of every flag the shared ``add_*_args`` builders define.

    docs/serving.md embeds this output between its ``FLAG_TABLE`` markers and
    ``ci/check_docs.py`` regenerates/diffs it, so the documented flag
    reference cannot drift from the argparse definitions.  Defaults are the
    builders' own — a changed default is a docs change by construction.
    """
    rows = [
        "| flag | group | default | meaning |",
        "| --- | --- | --- | --- |",
    ]
    for build in (add_service_args, add_workload_args, add_fault_args):
        group = build.__name__.removeprefix("add_").removesuffix("_args")
        ap = argparse.ArgumentParser(add_help=False)
        build(ap)
        for act in ap._actions:
            flag = ", ".join(f"`{s}`" for s in act.option_strings)
            default = "off" if act.const is True else f"`{act.default}`"
            rows.append(f"| {flag} | {group} | {default} | {act.help or ''} |")
    return "\n".join(rows)


def build_injector(args) -> Optional[FaultInjector]:
    if not (args.p_codec or args.p_dispatch or args.p_oom or args.p_slow
            or args.p_session_append or args.p_session_journal):
        return None
    return FaultInjector(
        FaultConfig(
            p_codec=args.p_codec,
            p_dispatch=args.p_dispatch,
            p_oom=args.p_oom,
            p_slow=args.p_slow,
            slow_s=args.slow_s,
            p_session_append=args.p_session_append,
            p_session_journal=args.p_session_journal,
            max_per_site=args.max_per_site,
        ),
        seed=args.seed,
    )


def build_service(args, pipeline_depth: Optional[int] = None) -> FFCzService:
    """One service from parsed flags; ``pipeline_depth`` overrides the flag
    (the bench builds matched serial/pipelined pairs this way)."""
    return FFCzService(
        get_compressor(args.base),
        config=ServiceConfig(
            max_batch=args.max_batch,
            block=args.block,
            deadline_s=args.deadline_s,
            max_retries=args.max_retries,
            seed=args.seed,
            pipeline_depth=args.pipeline_depth if pipeline_depth is None else pipeline_depth,
            max_queue=args.max_queue,
            max_sessions=args.max_sessions,
            session_lease_s=args.session_lease,
            session_journal_dir=args.session_journal_dir,
        ),
        injector=build_injector(args),
    )


def field_config(args) -> FFCzConfig:
    return FFCzConfig(E_rel=args.e_rel, Delta_rel=args.delta_rel, max_iters=300,
                      verify=False, crc=args.crc)


def submit_session(svc: FFCzService, rng: np.random.Generator, args) -> List[str]:
    """Queue one live session's workload: ``--session-frames`` coherent
    appends, one duplicate retry of the last frame, and a finalize.  Falls
    back to a whole-field request when session admission rejects."""
    cfg = field_config(args)
    edge = args.field_size
    try:
        sid = svc.open_session(cfg, TemporalConfig(mode="field", keyframe_interval=4))
    except ResourceExhausted:
        return [svc.submit_compress(rng.standard_normal((edge, edge)).astype(np.float32), cfg)]
    uids = []
    x = rng.standard_normal((edge, edge)).astype(np.float32)
    n_frames = max(1, args.session_frames)
    for t in range(n_frames):
        last = x
        uids.append(svc.submit_append(sid, t, x))
        x = x + 0.05 * rng.standard_normal((edge, edge)).astype(np.float32)
    # a client retry after an ambiguous failure: same seq, same content
    uids.append(svc.submit_append(sid, n_frames - 1, last))
    uids.append(svc.submit_finalize(sid))
    return uids


def submit_mixed(svc: FFCzService, rng: np.random.Generator, args, n: int) -> List[str]:
    """Queue ``n`` mixed compression requests drawn from the workload flags."""
    cfg = field_config(args)
    edge = args.field_size
    uids = []
    for _ in range(n):
        draw = rng.random()
        if draw < args.session_frac:
            uids.extend(submit_session(svc, rng, args))
        elif draw < args.session_frac + (1 - args.session_frac) * args.pencil_frac:
            size = int(rng.integers(args.block // 2, 4 * args.block))
            uids.append(svc.submit_pencils(rng.standard_normal(size).astype(np.float32),
                                           args.e_rel, args.delta_rel))
        else:
            uids.append(svc.submit_compress(rng.standard_normal((edge, edge)).astype(np.float32),
                                            cfg))
    return uids


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=(
            "Full flag reference (with the serving error taxonomy and "
            "degradation ladder): docs/serving.md — its flag table is "
            "generated from this module's add_*_args builders by "
            "ci/check_docs.py, so it cannot drift from what --help shows."
        ),
    )
    ap.add_argument("--requests", type=int, default=16, help="total requests to generate")
    add_service_args(ap)
    add_workload_args(ap)
    add_fault_args(ap)
    args = ap.parse_args()

    svc = build_service(args)
    injector = svc.injector
    rng = np.random.default_rng(args.seed)
    submit_mixed(svc, rng, args, args.requests)
    responses = dict(svc.drain())

    # feed a sample of the produced blobs back through decode (session
    # appends ack with receipts, not bytes — only containers decode)
    blobs = [r.payload for r in responses.values() if r.ok and isinstance(r.payload, bytes)]
    for i, blob in enumerate(blobs):
        if args.corrupt_frac and rng.random() < args.corrupt_frac:
            blob = injector.corrupt_blob(blob) if injector else blob[: len(blob) // 2]
        responses[svc.submit_decompress(blob, uid=f"dec-{i}")] = None
    responses.update(svc.drain())
    svc.close()

    lat = []
    for uid in responses:  # drain() already ordered by submission
        r = responses[uid]
        if r is None:
            continue
        lat.append(r.stats.latency_s)
        rungs = ",".join(r.stats.rungs) or "-"
        if r.ok:
            if isinstance(r.payload, bytes):
                size = len(r.payload)
            elif hasattr(r.payload, "n_bytes"):  # session FrameReceipt
                size = r.payload.n_bytes
            elif hasattr(r.payload, "size"):  # decompressed ndarray
                size = r.payload.size
            else:  # flush byte counts, abort acks
                size = r.payload
            print(f"{uid:>8}  ok        rungs={rungs}  bytes/elems={size}")
        else:
            print(f"{uid:>8}  REJECTED  rungs={rungs}  {r.error['type']}: {r.error['message']}")
    lat = np.sort(np.asarray(lat))
    p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
    print(f"\n{len(lat)} requests drained  p50={p50 * 1e3:.1f}ms  p99={p99 * 1e3:.1f}ms")
    print("counters:", dict(svc.counters))
    print("stage timers (s):", {k: round(v, 4) for k, v in svc.timers.items()})


if __name__ == "__main__":
    main()
