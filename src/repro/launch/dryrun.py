import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:

  * 16x16 single-pod mesh AND 2x16x16 multi-pod mesh,
  * every assigned architecture x its runnable input shapes,
  * ``.lower().compile()`` must succeed; we record memory_analysis(),
    cost_analysis(), and the collective-bytes breakdown parsed from the
    optimized HLO (inputs to EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out dryrun_results.json [--resume]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402


COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<ty>[a-z0-9]+)\[(?P<dims>[0-9,]*)\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
TUPLE_RE = re.compile(
    r"=\s*\((?P<tup>[^)]*)\)\s*(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(ty: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(ty, 4)


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in optimized HLO."""
    per_op = {}
    for line in hlo_text.splitlines():
        m = TUPLE_RE.search(line)
        if m:
            op = m.group("op")
            tot = sum(_shape_bytes(t, d) for t, d in SHAPE_RE.findall(m.group("tup")))
            per_op[op] = per_op.get(op, 0) + tot
            continue
        m = COLLECTIVE_RE.search(line)
        if m and m.group("ty"):
            op = m.group("op")
            per_op[op] = per_op.get(op, 0) + _shape_bytes(m.group("ty"), m.group("dims"))
    return per_op


def run_cell(arch: str, shape_id: str, multi_pod: bool, extra_cfg=None):
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_step

    cfg = get_config(arch, **(extra_cfg or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    step, args, in_sh, out_sh = make_step(cfg, shape_id, mesh)
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)

    # Trip-count-aware graph walk: XLA's cost_analysis counts scan bodies
    # once; our layer stacks are scans, so the corrected numbers come from
    # repro.launch.hlo_cost (see that module's docstring).
    from repro.launch.hlo_cost import analyze_hlo

    graph = analyze_hlo(hlo_text)
    result = {
        "arch": arch,
        "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": graph.flops,
        "bytes_accessed": graph.bytes,
        "collective_bytes": graph.collectives,
        "unknown_trip_whiles": graph.unknown_trips,
        "xla_cost_flops_bodyonce": cost.get("flops", 0.0),
        "xla_cost_bytes_bodyonce": cost.get("bytes accessed", 0.0),
        "collective_bytes_bodyonce": coll,
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--resume", action="store_true", help="skip cells already in --out")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, get_config

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r.get("ok")}

    for arch in archs:
        cfg = get_config(arch)
        shapes = list(cfg.cells()) if args.shape == "all" else args.shape.split(",")
        for shape_id in shapes:
            if shape_id not in cfg.cells():
                results.append(
                    {"arch": arch, "shape": shape_id, "skipped": True,
                     "reason": "full-attention arch: long_500k requires sub-quadratic decode state"}
                )
                continue
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                if (arch, shape_id, mesh_name) in done:
                    continue
                label = f"{arch} x {shape_id} x {mesh_name}"
                print(f"[dryrun] {label} ...", flush=True)
                try:
                    r = run_cell(arch, shape_id, multi)
                    print(
                        f"[dryrun] {label} OK lower={r['lower_s']}s compile={r['compile_s']}s "
                        f"flops={r['flops']:.3e} coll={sum(r['collective_bytes'].values()):.3e}B",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001 — record and continue
                    r = {
                        "arch": arch, "shape": shape_id, "mesh": mesh_name,
                        "ok": False, "error": f"{type(e).__name__}: {e}",
                    }
                    print(f"[dryrun] {label} FAIL: {r['error']}", flush=True)
                    if args.verbose:
                        traceback.print_exc()
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results if r.get("ok"))
    n_fail = sum(1 for r in results if r.get("ok") is False)
    n_skip = sum(1 for r in results if r.get("skipped"))
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped -> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
