"""Step functions (train / prefill / serve) + their sharding assignments.

``make_step(cfg, kind, mesh)`` returns (fn, in_shardings, out_shardings,
abstract_args) ready for ``jax.jit(...).lower(...).compile()`` — used by the
dry-run, the trainer, and the serving engine alike.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ArchConfig
from repro.launch.specs import input_specs, param_specs
from repro.models.model import ModelBundle, build_model
from repro.optim.adamw import AdamW
from repro.optim.grad_compress import compress_gradients
from repro.sharding.rules import batch_pspec, cache_pspecs, param_pspecs, to_shardings


def make_train_step(bundle: ModelBundle, optimizer: AdamW):
    cfg = bundle.cfg
    comp = cfg.compression

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(bundle.loss)(params, batch)
        if comp.grad_compression:
            grads = compress_gradients(
                grads,
                bits=comp.grad_bits,
                E_rel=comp.grad_E_rel,
                Delta_rel=comp.grad_Delta_rel,
                block=comp.grad_block,
            )
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_prefill_step(bundle: ModelBundle):
    def prefill_step(params, batch, cache):
        return bundle.prefill(params, batch, cache)

    return prefill_step


def make_serve_step(bundle: ModelBundle):
    def serve_step(params, tokens, cache):
        return bundle.decode(params, tokens, cache)

    return serve_step


def make_step(cfg: ArchConfig, shape_id: str, mesh, optimizer: AdamW | None = None):
    """Build (step_fn, args_abstract, in_shardings, out_shardings)."""
    import dataclasses as _dc

    # inject mesh axes so model code can place adaptive sharding constraints
    cfg = _dc.replace(cfg, mesh_axes=tuple(zip(mesh.axis_names, mesh.devices.shape)))
    bundle = build_model(cfg)
    seq, batch, kind = SHAPES[shape_id]
    optimizer = optimizer or AdamW()

    p_abs = param_specs(cfg)
    p_spec = param_pspecs(p_abs, mesh)
    p_shard = to_shardings(p_spec, mesh)
    specs = input_specs(cfg, shape_id)

    if kind == "train":
        step = make_train_step(bundle, optimizer)
        opt_abs = jax.eval_shape(optimizer.init, p_abs)
        opt_shard = to_shardings(optimizer.state_pspecs(p_spec), mesh)
        b_shard = to_shardings(batch_pspec(specs["batch"], mesh), mesh)
        args = (p_abs, opt_abs, specs["batch"])
        in_sh = (p_shard, opt_shard, b_shard)
        out_sh = (p_shard, opt_shard, NamedSharding(mesh, P()))
        return step, args, in_sh, out_sh

    c_shard = to_shardings(cache_pspecs(specs["cache"], mesh), mesh)
    if kind == "prefill":
        step = make_prefill_step(bundle)
        b_shard = to_shardings(batch_pspec(specs["batch"], mesh), mesh)
        args = (p_abs, specs["batch"], specs["cache"])
        in_sh = (p_shard, b_shard, c_shard)
        logits_sh = NamedSharding(mesh, _logits_spec(specs["batch"], mesh))
        out_sh = (logits_sh, c_shard)
        return step, args, in_sh, out_sh

    if kind == "decode":
        step = make_serve_step(bundle)
        t_shard = to_shardings(batch_pspec({"tokens": specs["tokens"]}, mesh), mesh)["tokens"]
        args = (p_abs, specs["tokens"], specs["cache"])
        in_sh = (p_shard, t_shard, c_shard)
        logits_sh = NamedSharding(mesh, _logits_spec({"tokens": specs["tokens"]}, mesh))
        out_sh = (logits_sh, c_shard)
        return step, args, in_sh, out_sh

    raise ValueError(kind)


def _logits_spec(batch_specs_dict, mesh) -> P:
    """Logits (b, s, V): batch over DP axes when divisible, vocab on model."""
    spec = batch_pspec(batch_specs_dict, mesh)["tokens"]
    b_axis = spec[0] if len(spec) else None
    return P(b_axis, None, "model")
