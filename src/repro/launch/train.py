"""Production train entry point.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --preset smoke \
        --steps 100 --ckpt-dir /tmp/run1

On a real TPU pod this runs under the production mesh (one process per host,
jax.distributed.initialize); on CPU it runs the same code path on the host
mesh.  Restart-from-checkpoint, straggler tracking, and FFCz gradient /
checkpoint compression are wired through the same Trainer the tests use.
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.configs import CompressionConfig, get_config, get_smoke_config
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_run")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-compression", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.preset == "full" else get_smoke_config(args.arch)
    cfg = dataclasses.replace(
        cfg,
        compression=CompressionConfig(
            grad_compression=args.grad_compression,
            checkpoint_compression=args.ckpt_compression,
        ),
    )
    run = TrainerConfig(
        seq_len=args.seq_len, global_batch=args.global_batch, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, inject_failure_at=args.inject_failure_at,
    )
    tr = Trainer(cfg, run)
    out = tr.train(args.steps)
    print(f"done: step={out['final_step']} loss={out['final_loss']:.4f} "
          f"stragglers={len(out['straggler_events'])}")


if __name__ == "__main__":
    main()
