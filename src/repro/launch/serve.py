"""Production serve entry point: batched decode over a request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 8
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.configs import CompressionConfig, get_config, get_smoke_config
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kv-compression", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.preset == "full" else get_smoke_config(args.arch)
    cfg = dataclasses.replace(
        cfg, compression=CompressionConfig(kv_cache_compression=args.kv_compression)
    )
    eng = ServingEngine(cfg, ServeConfig(max_batch=args.max_batch))
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab, int(rng.integers(4, 20))),
                   max_new_tokens=args.max_new_tokens)
    served = 0
    while eng.queue:
        for r in eng.step():
            served += 1
            print(f"uid={r['uid']}: {r['tokens']}")
    print(f"served {served} requests")


if __name__ == "__main__":
    main()
