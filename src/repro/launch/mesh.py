"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization, and smoke
tests/benches must keep seeing 1 CPU device.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods = 512 chips
as (pod=2, data=16, model=16); the pod axis is the outer data-parallel/FSDP
axis (gradient all-reduce crosses the pod interconnect; see sharding/rules).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic re-planning, tests)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: Optional[int] = None):
    """Mesh over whatever devices exist locally (tests / CPU examples)."""
    n = len(jax.devices())
    mp = model_parallel or 1
    assert n % mp == 0
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def data_axes(mesh) -> Tuple[str, ...]:
    """All data-parallel axes of a mesh (pod is outer DP when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
