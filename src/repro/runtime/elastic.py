"""Elastic re-planning: rebuild the mesh from surviving devices.

Checkpoints store full (host-gathered) arrays, so elasticity reduces to
(1) choosing a new (pods, data, model) factorization for the surviving
device count and (2) re-entering the jitted step with the new mesh's
in_shardings — no state surgery.

Planning policy: keep TP ("model") as close to the requested degree as the
device count allows (TP degree is tied to weight-dim divisibility), give the
rest to DP; drop the pod axis when a whole pod is lost.
"""

from __future__ import annotations

from typing import Tuple

import jax


def plan_mesh_shape(n_devices: int, preferred_model: int = 16) -> Tuple[Tuple[int, int], Tuple[str, str]]:
    """Largest model-parallel degree <= preferred that divides n_devices."""
    mp = min(preferred_model, n_devices)
    while mp > 1 and n_devices % mp != 0:
        mp -= 1
    return (n_devices // mp, mp), ("data", "model")


def replan_mesh(n_devices: int, preferred_model: int = 16):
    shape, axes = plan_mesh_shape(n_devices, preferred_model)
    return jax.make_mesh(shape, axes)


def survivors_after_pod_loss(total: int = 512, pods: int = 2, lost_pods: int = 1) -> int:
    return total // pods * (pods - lost_pods)
