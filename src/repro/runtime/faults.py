"""Deterministic fault injection for exercising the FFCz service failure path.

The chaos suite (tests/test_faults.py) needs the *same* faults on every run:
a flaky test that only sometimes exercises the retry ladder proves nothing.
Since the pipelined service (ISSUE 7) runs a request's ENCODE on a worker
thread while the scheduler thread dispatches the next bucket's EXECUTE, the
*global* wall-clock order of ``fire`` calls is no longer deterministic — it
depends on thread interleaving.  What IS deterministic, in serial and
pipelined mode alike, is the per-request order: a request's plan always
precedes its base codec call, which precedes its execute attempts, which
precede its encode attempts.  So every probabilistic decision here flows
from a *per-request* seeded ``np.random.default_rng`` substream (derived
from ``(seed, uid)``), and the fire cap is counted per ``(site, uid)``:
given the same seed and the same per-request sequence of ``fire`` calls,
the same faults fire — regardless of how requests interleave across
threads.  That is the property the chaos suite's serial-vs-pipelined
counter-parity test gates.

Injection sites mirror the real failure surface of the pipeline:

  ``codec``     host base-codec / entropy-coder failure (``OSError``-shaped,
                classified transient -> retried with backoff)
  ``dispatch``  device program dispatch failure (``RuntimeError``-shaped,
                transient -> retried; the service's ladder also descends
                fft_impl rungs when retries exhaust)
  ``oom``       device allocation failure (message carries the XLA
                ``RESOURCE_EXHAUSTED`` marker -> batch bisection).  Fused
                pencil buckets fire this site with the ORIGINAL bucket
                lead's uid through the whole bisect recursion, so the cap
                applies to the bucket as a unit, not per sub-bucket.
  ``slow``      the request takes ``slow_s`` longer than it should (tests the
                deadline path; returned as a delay, never an exception)

  ``session_append``   live-session append fails before the frame is encoded
                (``RuntimeError``-shaped -> retried); fired with the append
                request's uid so the sequence stays scheduling-invariant
  ``session_journal``  the write-ahead journal append fails after the frame
                encoded (``OSError``-shaped -> retried); the session keeps
                the encoded-but-unjournaled frame pending so the retry
                re-journals without re-encoding

plus two pure byte-corruption helpers (``flip_bit`` / ``truncate``) for the
decode-hardening fuzz tests (these draw from a plain shared stream — they
are test-harness primitives, not service-threaded sites).

``max_per_site`` caps how many times each site fires *per request* so an
injector with ``p=1.0`` still lets the work eventually succeed — that is
exactly the "transient" contract the retry ladder is built for.

All mutable state (per-request streams, fire counts) is guarded by a lock:
the pipelined service fires sites from both the scheduler thread and the
encode worker thread.

The service-side view of these sites (which stage fires what, and how each
classified error walks the degradation ladder) is docs/serving.md.
"""

from __future__ import annotations

import dataclasses
import threading
import zlib
from typing import Dict, Optional, Tuple

import numpy as np


class InjectedCodecError(OSError):
    """Injected host-codec failure (classifies as HostCodecError -> retry)."""


class InjectedDispatchError(RuntimeError):
    """Injected device-dispatch failure (classifies as DeviceDispatchError)."""


class InjectedOOM(RuntimeError):
    """Injected device allocation failure; the message carries the XLA OOM
    marker so :func:`repro.core.errors.is_oom` classifies it for bisection."""

    def __init__(self, message: str = "injected allocation failure"):
        # the marker must survive any caller-supplied message, or the error
        # classifies as a plain dispatch failure and gets retried not bisected
        super().__init__(f"RESOURCE_EXHAUSTED: {message}")


class InjectedJournalError(OSError):
    """Injected session-journal write failure (``OSError``-shaped, so it
    classifies as HostCodecError -> retried; the write-ahead discipline means
    the un-acked frame is simply re-journaled on the retry)."""


class InjectedAppendError(RuntimeError):
    """Injected session-append failure before the frame is encoded
    (``RuntimeError``-shaped -> DeviceDispatchError -> retried)."""


_SITE_ERRORS = {
    "codec": InjectedCodecError,
    "dispatch": InjectedDispatchError,
    "oom": InjectedOOM,
    "session_journal": InjectedJournalError,
    "session_append": InjectedAppendError,
}


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-site fire probabilities and knobs for one injector."""

    p_codec: float = 0.0
    p_dispatch: float = 0.0
    p_oom: float = 0.0
    p_slow: float = 0.0
    p_session_journal: float = 0.0
    p_session_append: float = 0.0
    slow_s: float = 0.0  # extra latency charged to a request when "slow" fires
    # Per-(site, request) fire cap: after this many fires a site goes quiet
    # for that request, so even p=1.0 faults stay transient and the retry
    # ladder can drain the queue.
    max_per_site: int = 2

    def probability(self, site: str) -> float:
        try:
            return {
                "codec": self.p_codec,
                "dispatch": self.p_dispatch,
                "oom": self.p_oom,
                "slow": self.p_slow,
                "session_journal": self.p_session_journal,
                "session_append": self.p_session_append,
            }[site]
        except KeyError:
            raise ValueError(f"unknown fault site {site!r}") from None


class FaultInjector:
    """Seeded, thread-safe source of faults; ``None`` config or all-zero
    probabilities makes every call a no-op, so production code paths can call
    into an always-present injector unconditionally."""

    def __init__(self, config: Optional[FaultConfig] = None, seed: int = 0):
        self.config = config or FaultConfig()
        self.seed = seed
        self._rng = np.random.default_rng(seed)  # corruption primitives only
        self._lock = threading.Lock()
        self._streams: Dict[str, np.random.Generator] = {}
        self.fired: Dict[Tuple[str, str], int] = {}

    # -- exception sites --------------------------------------------------

    def fire(self, site: str, uid: str = "") -> None:
        """Raise the site's injected error if the (seeded) die says so.

        The decision comes from the request's own ``(seed, uid)`` substream,
        so it depends only on the per-request call sequence — never on how
        requests from different buckets interleave across service threads.
        """
        if not self._draw(site, uid):
            return
        exc_type = _SITE_ERRORS[site]
        raise exc_type(f"injected {site} fault (uid={uid})")

    def sleep_s(self, uid: str = "") -> float:
        """Extra latency to charge the current request (0.0 when the ``slow``
        site does not fire).  Returned, not slept: the service adds it to the
        request's clock so deadline tests stay fast."""
        return self.config.slow_s if self._draw("slow", uid) else 0.0

    def _stream(self, uid: str) -> np.random.Generator:
        # one substream per request: crc32(uid) folds the uid into the seed
        # material deterministically across processes (unlike hash())
        if uid not in self._streams:
            self._streams[uid] = np.random.default_rng(
                [self.seed, zlib.crc32(uid.encode("utf-8"))]
            )
        return self._streams[uid]

    def _draw(self, site: str, uid: str) -> bool:
        p = self.config.probability(site)
        with self._lock:
            if p <= 0.0:
                return False
            if self.fired.get((site, uid), 0) >= self.config.max_per_site:
                return False
            # Always consume exactly one draw per call so a request's
            # fire/no-fire sequence is reproducible regardless of which
            # sites are enabled.
            hit = bool(self._stream(uid).random() < p)
            if hit:
                self.fired[(site, uid)] = self.fired.get((site, uid), 0) + 1
            return hit

    # -- byte corruption (decode fuzzing) ---------------------------------

    def flip_bit(self, blob: bytes, position: Optional[int] = None) -> bytes:
        """Return ``blob`` with one bit flipped (seeded position by default)."""
        if not blob:
            return blob
        if position is None:
            position = int(self._rng.integers(0, len(blob) * 8))
        byte_i, bit_i = divmod(position, 8)
        out = bytearray(blob)
        out[byte_i] ^= 1 << bit_i
        return bytes(out)

    def truncate(self, blob: bytes, keep: Optional[int] = None) -> bytes:
        """Return a truncated prefix of ``blob`` (seeded length by default)."""
        if keep is None:
            keep = int(self._rng.integers(0, len(blob)))
        return blob[:keep]

    def corrupt_blob(self, blob: bytes) -> bytes:
        """Randomly flip a bit or truncate — the mixed-mode fuzz primitive."""
        if self._rng.random() < 0.5:
            return self.flip_bit(blob)
        return self.truncate(blob)
