"""Fault-tolerant trainer: restart, straggler mitigation, failure injection.

Production behaviours implemented (and exercised by tests on the host mesh):

  * restart-from-latest: construction restores the newest committed
    checkpoint; the data pipeline is counter-mode so the token stream resumes
    exactly at the restored step.
  * periodic + async checkpointing (save overlaps the next step).
  * straggler mitigation: per-step deadline tracked against a running median;
    a step exceeding ``straggler_factor`` x median is recorded and the
    deadline logic is exposed for an external scheduler to preempt (on real
    pods this triggers slice re-planning; on CPU it is bookkeeping that tests
    assert on).
  * failure injection: ``inject_failure_at`` raises mid-run to simulate a
    node loss; tests then rebuild a Trainer and verify bit-exact resume.
  * elasticity: ``runtime.elastic.plan_mesh`` re-plans (data, model) from the
    surviving device count; full-array checkpoints reshard on restore.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.codec import CheckpointCodec
from repro.checkpoint.manager import CheckpointManager
from repro.configs import ArchConfig
from repro.data.pipeline import pipeline_for
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.optim.adamw import AdamW
from repro.sharding.rules import batch_pspec, param_pspecs, to_shardings


@dataclasses.dataclass
class TrainerConfig:
    seq_len: int = 128
    global_batch: int = 8
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    ckpt_async: bool = True
    keep: int = 3
    seed: int = 0
    straggler_factor: float = 3.0
    inject_failure_at: Optional[int] = None
    log_every: int = 10


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, arch_cfg: ArchConfig, run_cfg: TrainerConfig, mesh=None, optimizer: Optional[AdamW] = None):
        self.cfg = arch_cfg
        self.run = run_cfg
        self.mesh = mesh
        self.optimizer = optimizer or AdamW(warmup_steps=10)
        self.bundle = build_model(arch_cfg)
        self.pipeline = pipeline_for(arch_cfg, run_cfg.seq_len, run_cfg.global_batch, seed=run_cfg.seed)
        codec = CheckpointCodec(
            enabled=arch_cfg.compression.checkpoint_compression,
            E_rel=arch_cfg.compression.ckpt_E_rel,
            Delta_rel=arch_cfg.compression.ckpt_Delta_rel,
        )
        self.ckpt = CheckpointManager(run_cfg.ckpt_dir, codec=codec, keep=run_cfg.keep)
        self.step_times: List[float] = []
        self.straggler_events: List[Dict[str, Any]] = []
        self.metrics: List[Dict[str, Any]] = []

        step_fn = make_train_step(self.bundle, self.optimizer)
        if mesh is not None:
            p_abs = jax.eval_shape(self.bundle.init, jax.random.PRNGKey(run_cfg.seed))
            p_spec = param_pspecs(p_abs, mesh)
            p_sh = to_shardings(p_spec, mesh)
            o_sh = to_shardings(self.optimizer.state_pspecs(p_spec), mesh)
            b_abs = jax.eval_shape(lambda: self.pipeline.batch_at(0))
            b_sh = to_shardings(batch_pspec(b_abs, mesh), mesh)
            self._step = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh), donate_argnums=(0, 1))
        else:
            self._step = jax.jit(step_fn, donate_argnums=(0, 1))

        # restart-from-latest (fault tolerance)
        self.params = None
        self.opt_state = None
        self.start_step = 0
        like = jax.eval_shape(
            lambda k: (self.bundle.init(k), self.optimizer.init(self.bundle.init(k))),
            jax.random.PRNGKey(run_cfg.seed),
        )
        restored = self.ckpt.restore_latest(like)
        if restored is not None:
            self.start_step, (self.params, self.opt_state) = restored
            print(f"[trainer] restored checkpoint at step {self.start_step}")
        else:
            self.params = self.bundle.init(jax.random.PRNGKey(run_cfg.seed))
            self.opt_state = self.optimizer.init(self.params)

    # ------------------------------------------------------------------

    def train(self, num_steps: int) -> Dict[str, Any]:
        mesh_ctx = self.mesh if self.mesh is not None else _NullCtx()
        step = self.start_step
        end = self.start_step + num_steps
        with mesh_ctx:
            while step < end:
                if self.run.inject_failure_at is not None and step == self.run.inject_failure_at:
                    self.run.inject_failure_at = None
                    raise SimulatedFailure(f"injected node failure at step {step}")
                t0 = time.time()
                batch = self.pipeline.batch_at(step)
                self.params, self.opt_state, loss = self._step(self.params, self.opt_state, batch)
                loss = float(loss)
                dt = time.time() - t0
                self._track_straggler(step, dt)
                step += 1
                if step % self.run.log_every == 0 or step == end:
                    self.metrics.append({"step": step, "loss": loss, "dt": dt})
                if step % self.run.ckpt_every == 0 or step == end:
                    self.ckpt.save(step, (self.params, self.opt_state), blocking=not self.run.ckpt_async)
        self.ckpt.wait()
        self.start_step = step
        return {"final_step": step, "final_loss": loss, "metrics": self.metrics,
                "straggler_events": self.straggler_events}

    def _track_straggler(self, step: int, dt: float) -> None:
        self.step_times.append(dt)
        window = self.step_times[-50:]
        if len(window) >= 5:
            med = statistics.median(window)
            if dt > self.run.straggler_factor * med:
                self.straggler_events.append({"step": step, "dt": dt, "median": med})


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
