"""End-to-end serving driver: batched requests through the decode engine,
with and without FFCz KV-cache compression.

    PYTHONPATH=src:. python examples/serve_batched.py --arch qwen2-0.5b --requests 6
"""

import argparse
import dataclasses
import time

import numpy as np

from repro.configs import CompressionConfig, get_smoke_config
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    base = get_smoke_config(args.arch)

    for kv_comp in (False, True):
        if kv_comp and base.family == "ssm":
            print("kv compression inapplicable to attention-free arch (no KV cache); skipping")
            continue
        cfg = dataclasses.replace(
            base, compression=CompressionConfig(kv_cache_compression=kv_comp,
                                                kv_E_rel=1e-3, kv_Delta_rel=1e-2)
        )
        eng = ServingEngine(cfg, ServeConfig(max_batch=args.max_batch), rng_seed=0)
        for i in range(args.requests):
            plen = int(rng.integers(4, 16))
            eng.submit(rng.integers(0, cfg.vocab, plen), max_new_tokens=args.max_new_tokens)
        t0 = time.perf_counter()
        done = []
        while eng.queue:
            done += eng.step()
        dt = time.perf_counter() - t0
        tok_s = sum(len(r["tokens"]) for r in done) / dt
        print(f"kv_compression={kv_comp}: served {len(done)} requests, "
              f"{tok_s:.1f} tok/s")
        for r in done[:3]:
            print(f"  uid={r['uid']}: {r['tokens']}")


if __name__ == "__main__":
    main()
