"""Stream a multi-channel EEG recording through the temporal codec.

    PYTHONPATH=src:. python examples/stream_eeg.py
    PYTHONPATH=src:. python examples/stream_eeg.py --quick   # tiny, CI docs leg

EEG is the forcing scenario for the pencil path (docs/streaming.md): each
frame is ``(channels, samples)`` — 1-D-per-channel x time — so the stream
routes through ``correct_batch`` with one pencil per channel row, not the
whole-field rfftn.  The demo:

1. synthesizes a slowly evolving multi-channel recording (per-channel 1/f
   "pink" EEG character + a drifting shared component — the temporal
   coherence the predictor and the POCS warm start exploit),
2. compresses it with ``TemporalCodec`` (linear predictor, keyframe every 8
   frames, ``warm_start=True``),
3. re-verifies BOTH claimed bounds on every decoded frame — keyframes and
   residual frames alike — against the stream header's (E, Delta),
4. seeks to an arbitrary frame via the FFCS index and checks the
   seek-decode is bitwise identical to the sequential decode,
5. prints per-frame POCS iteration counts (residual frames warm-start from
   the previous frame's edit spectrum; the controlled warm-vs-cold
   iteration measurement is the ``stream/warm-vs-cold`` row recorded by
   ``benchmarks/bench_pocs.py``).
"""

import argparse

import numpy as np

from repro.compressors import get_compressor
from repro.configs.ffcz_fields import FieldConfig
from repro.core.ffcz import FFCzConfig
from repro.core.temporal import TemporalCodec, TemporalConfig, TemporalStream
from repro.data.fields import make_field


def make_eeg_frames(n_frames: int, channels: int, samples: int, seed: int = 0):
    """Coherent synthetic EEG: per-channel pink noise + drifting shared mode."""
    rng = np.random.default_rng(seed)
    chans = np.stack([
        make_field(FieldConfig(f"ch{c}", (samples,), "pink", alpha=1.0, seed=seed + c))
        for c in range(channels)
    ])
    shared = make_field(FieldConfig("shared", (samples,), "pink", alpha=1.0, seed=seed + 999))
    drift = 0.03 * rng.standard_normal((channels, 1)).astype(np.float32)
    frames = []
    for t in range(n_frames):
        wobble = 0.01 * rng.standard_normal((channels, samples)).astype(np.float32)
        frames.append((chans + (t * drift) * shared + wobble).astype(np.float32))
    return frames


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="tiny stream (the CI docs leg)")
    ap.add_argument("--frames", type=int, default=None)
    ap.add_argument("--channels", type=int, default=None)
    ap.add_argument("--samples", type=int, default=None)
    args = ap.parse_args()

    n_frames = args.frames or (6 if args.quick else 24)
    channels = args.channels or (4 if args.quick else 16)
    samples = args.samples or (64 if args.quick else 512)

    frames = make_eeg_frames(n_frames, channels, samples)
    raw_bytes = sum(f.nbytes for f in frames)
    print(f"stream: {n_frames} frames x ({channels} ch, {samples} samples) "
          f"= {raw_bytes/1e3:.1f} kB float32")

    codec = TemporalCodec(
        get_compressor("szlike"),
        FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=300, warm_start=True),
        # block=0 -> one pencil per channel row (the EEG routing)
        stream=TemporalConfig(mode="pencils", predictor="linear", keyframe_interval=8),
    )

    enc = codec.open_stream()
    for f in frames:
        enc.add_frame(f)
    blob = enc.finish()
    print(f"FFCS container: {len(blob)} bytes ({raw_bytes/len(blob):.1f}x)")

    # 3. per-frame dual-bound verification against the stream-level claim
    stream = TemporalStream.from_bytes(blob)
    E0, D0 = stream.E, stream.Delta
    decoded = codec.decompress_stream(blob)
    worst_e = worst_d = 0.0
    for t, (x, xh) in enumerate(zip(frames, decoded)):
        eps = xh.astype(np.float64) - x.astype(np.float64)
        flat = eps.reshape(-1)
        tiles = np.pad(flat, (0, (-flat.size) % stream.block)).reshape(-1, stream.block)
        d = np.fft.rfft(tiles, axis=-1)
        e, dm = np.abs(eps).max(), max(np.abs(d.real).max(), np.abs(d.imag).max())
        worst_e, worst_d = max(worst_e, e), max(worst_d, dm)
        kind = "KEY" if stream.is_keyframe(t) else "res"
        st = enc.frame_stats[t]
        print(f"  frame {t:2d} [{kind}]  pocs_iters={st['iterations']:3d}  "
              f"|eps|={e:.3e}  |dhat|={dm:.3e}")
        assert e <= E0 and dm <= D0, f"frame {t} violated the stream bound"
    print(f"bounds held on every frame: worst |eps|={worst_e:.3e} <= E={E0:.3e}, "
          f"worst |dhat|={worst_d:.3e} <= Delta={D0:.3e}")

    # 4. seek: decode one frame via the index, compare to sequential decode
    t_seek = n_frames - 2
    k = stream.latest_keyframe(t_seek)
    x_seek = codec.decode_frame(blob, t_seek)
    assert np.array_equal(x_seek, decoded[t_seek])
    print(f"seek to frame {t_seek}: decoded {t_seek - k + 1} frames "
          f"(keyframe {k} -> {t_seek}), bitwise == sequential decode")

    # 5. warm start: residual frames vs cold keyframes
    cold = [s["iterations"] for s in enc.frame_stats if s["keyframe"]]
    warm = [s["iterations"] for s in enc.frame_stats if not s["keyframe"]]
    if warm:
        print(f"POCS iterations: keyframes (cold) mean {np.mean(cold):.1f}, "
              f"residuals (warm) mean {np.mean(warm):.1f}")


if __name__ == "__main__":
    main()
