"""End-to-end training driver: any assigned arch, fault-tolerant runtime,
optional FFCz gradient + checkpoint compression.

    # fast CPU demo (reduced config):
    PYTHONPATH=src:. python examples/train_lm.py --arch qwen2-0.5b --steps 50

    # ~100M-param run (the full e2e deliverable; slow on 1 CPU core):
    PYTHONPATH=src:. python examples/train_lm.py --arch qwen2-0.5b --preset 100m --steps 300

    # full published config on a real pod:
    PYTHONPATH=src:. python examples/train_lm.py --arch qwen2-7b --preset full ...
"""

import argparse
import dataclasses

from repro.configs import CompressionConfig, get_config, get_smoke_config
from repro.runtime.trainer import Trainer, TrainerConfig


def build_cfg(arch: str, preset: str, grad_comp: bool, ckpt_comp: bool):
    if preset == "smoke":
        cfg = get_smoke_config(arch)
    elif preset == "100m":
        # ~100M params in the arch's own family
        cfg = dataclasses.replace(
            get_smoke_config(arch),
            n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
            d_ff=2048, vocab=32000, dtype="float32",
        )
    elif preset == "full":
        cfg = get_config(arch)
    else:
        raise SystemExit(f"unknown preset {preset}")
    comp = CompressionConfig(
        grad_compression=grad_comp, checkpoint_compression=ckpt_comp,
        grad_E_rel=1e-2, grad_Delta_rel=1e-1, ckpt_E_rel=1e-5, ckpt_Delta_rel=1e-5,
    )
    return dataclasses.replace(cfg, compression=comp)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-compression", action="store_true")
    args = ap.parse_args()

    cfg = build_cfg(args.arch, args.preset, args.grad_compression, args.ckpt_compression)
    run = TrainerConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, log_every=10,
    )
    tr = Trainer(cfg, run)
    print(f"training {args.arch} [{args.preset}] from step {tr.start_step} for {args.steps} steps")
    out = tr.train(args.steps)
    for m in out["metrics"]:
        print(f"  step {m['step']:6d}  loss {m['loss']:.4f}  ({m['dt']*1e3:.0f} ms/step)")
    print(f"final step {out['final_step']}, loss {out['final_loss']:.4f}; "
          f"straggler events: {len(out['straggler_events'])}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
