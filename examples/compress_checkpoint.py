"""FFCz as training-infrastructure: compress a real model checkpoint with
dual-domain bounds and measure size + restore fidelity.

    PYTHONPATH=src:. python examples/compress_checkpoint.py
"""

import os
import tempfile

import jax
import numpy as np

from repro.checkpoint.codec import CheckpointCodec
from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.models.model import build_model


def main():
    cfg = get_smoke_config("qwen2-7b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    raw_bytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))

    for enabled, label in ((False, "raw"), (True, "ffcz(E_rel=1e-4)")):
        codec = CheckpointCodec(enabled=enabled, E_rel=1e-4, Delta_rel=1e-4)
        with tempfile.TemporaryDirectory() as td:
            mgr = CheckpointManager(td, codec=codec)
            mgr.save(0, params)
            stored = sum(
                os.path.getsize(os.path.join(td, d, f))
                for d in os.listdir(td)
                for f in os.listdir(os.path.join(td, d))
            )
            got = mgr.restore(0, jax.eval_shape(lambda: params))
            err = max(
                float(np.abs(np.asarray(a) - np.asarray(b)).max())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got))
            )
        print(f"{label:20s}: {stored/1e6:7.2f} MB (raw {raw_bytes/1e6:.2f} MB, "
              f"{raw_bytes/stored:.2f}x), max restore err {err:.2e}")
    print("note: random-init weights are near-incompressible (max-entropy); on trained\n"
          "checkpoints the prediction/transform stages find structure — the dual-domain\n"
          "guarantee (pointwise + spectral) is the point, the ratio follows the data.")


if __name__ == "__main__":
    main()
