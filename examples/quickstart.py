"""Quickstart: dual-domain error-bounded compression of a cosmology-like field.

    PYTHONPATH=src:. python examples/quickstart.py
    PYTHONPATH=src:. python examples/quickstart.py --quick   # small field, CI docs leg

Compresses a synthetic Nyx-like Gaussian random field (power-law spectrum)
with SZ3-like base + FFCz correction, prints both guarantees and the storage
breakdown, and verifies the power spectrum stays in the ribbon.
"""

import argparse

import numpy as np
import jax.numpy as jnp

from repro.compressors import get_compressor
from repro.configs.ffcz_fields import FieldConfig
from repro.core.ffcz import FFCz, FFCzConfig
from repro.core.spectrum import bitrate, power_spectrum_relative_error, psnr, ssnr_spatial
from repro.data.fields import make_field


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small field + one base compressor (the CI docs leg)")
    args = ap.parse_args()

    if args.quick:
        x = make_field(FieldConfig("quick", (24, 24, 24), "powerlaw", alpha=2.0))
        bases, max_iters = ("szlike",), 300
    else:
        x = make_field("nyx-like")
        bases, max_iters = ("szlike", "zfplike", "sperrlike"), 1500
    print(f"field: {'quick' if args.quick else 'nyx-like'} {x.shape} "
          f"({x.nbytes/1e6:.1f} MB float32)")

    for base_name in bases:
        base = get_compressor(base_name)
        codec = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=max_iters))
        xh, blob = codec.roundtrip(x)
        st = blob.stats
        print(f"\n=== base={base_name} ===")
        print(f"  POCS iterations      : {st.iterations} (converged={st.converged})")
        print(f"  active edits         : {st.n_active_spatial} spatial, {st.n_active_frequency} frequency")
        print(f"  bytes                : base={st.base_bytes}, edits={st.edit_bytes} "
              f"({100*st.edit_bytes/st.total_bytes:.1f}% overhead)")
        print(f"  compression ratio    : {x.nbytes/st.total_bytes:.1f}x  "
              f"(bitrate {bitrate(st.total_bytes, x.size):.4f} bits/value)")
        print(f"  spatial margin       : {st.spatial_margin:.3e} (>=0 -> |eps| <= E everywhere)")
        print(f"  frequency margin     : {st.frequency_margin:.3e} (>=0 -> |Re/Im delta| <= Delta everywhere)")
        print(f"  PSNR / SSNR          : {float(psnr(jnp.asarray(xh), jnp.asarray(x))):.1f} dB / "
              f"{float(ssnr_spatial(jnp.asarray(xh), jnp.asarray(x))):.1f} dB")

    # power-spectrum-preserving mode (paper Observation 4)
    codec = FFCz(get_compressor("szlike"),
                 FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3,
                            max_iters=300 if args.quick else 2500))
    xh, blob = codec.roundtrip(x)
    _, rel = power_spectrum_relative_error(xh, x)
    print("\n=== power-spectrum mode (pspec_rel=0.1%) ===")
    print(f"  max |P_hat(k)-P(k)|/P(k) over shells: {np.abs(rel[1:]).max():.2e} "
          f"(ribbon: 1.0e-03) -> {'WITHIN' if np.abs(rel[1:]).max() <= 1.05e-3 else 'OUTSIDE'}")


if __name__ == "__main__":
    main()
